"""In-kernel temporal blocking (fuse_strategy="inkernel"): the multi-step
Pallas sweep kernel with VMEM-resident intermediates, end to end.

The acceptance bar is BIT-exactness: an in-kernel T-step chunk performs the
same per-step banded-Toeplitz contractions as T sequential applications of
the same Pallas engine step (only the tile extents differ, and the extra
Toeplitz zeros contribute exact +0.0 terms), so the sweep must equal
``time_stepper.evolve`` of the engine's own step_fn to the last bit — not
just allclose.  That holds for the shape-preserving boundaries evolve can
drive (zero/periodic, the production sweep paths — asserted with
array_equal across the whole PAPER_SUITE in the slow tier).  Under
boundary='valid' the sequential reference re-tiles at a different padded
shape every step, and XLA:CPU's elementwise FMA fusion rounds shape-
dependently, so the valid comparison asserts a one-ulp-tight tolerance
instead.  The oracle check (vs the naive gather reference) guards
correctness of the shared arithmetic separately.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.core import stencil_spec as ss
from repro.core import temporal
from repro.core.engine import StencilEngine
from repro.core.time_stepper import evolve
from repro.kernels.ref import stencil_ref

SUITE = ss.PAPER_SUITE()
BOUNDARIES = ("valid", "zero", "periodic")
FAST_SPECS = ["box2d_r1", "star2d_r2", "diag2d_r1", "box3d_r1", "star3d_r1"]


def _grid_for(spec, steps, fuse):
    n = max(4 * spec.order * min(fuse, steps) + 4, 6 * spec.order + 6)
    if spec.ndim == 3:
        # keep 3-D interpret-mode grids small, but never below what the
        # total valid-mode shrink 2*r*steps needs to stay feasible
        n = min(n, max(20, 2 * spec.order * steps + 4))
    return (n,) * spec.ndim


def _evolve_ref(eng, x, steps, boundary):
    """Sequential evolution through the engine's OWN per-step fn."""
    if boundary == "valid":
        for _ in range(steps):           # evolve() needs static shapes
            x = eng.step_fn()(x)
        return x
    return evolve(eng.step_fn(), x, steps).state


def _check_inkernel(spec, boundary, steps=3, fuse=2):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=_grid_for(spec, steps, fuse)), jnp.float32)
    block = (16, 16) if spec.ndim == 2 else (4, 8, 8)
    eng = StencilEngine(spec, backend="pallas", block=block,
                        boundary=boundary)
    out = eng.sweep(x, steps, fuse=fuse, strategy="inkernel")
    seq = _evolve_ref(eng, x, steps, boundary)
    if boundary == "valid":
        # the shrinking grid re-tiles the per-step reference at a new
        # padded shape every step; XLA:CPU fuses the elementwise adds
        # shape-dependently, so only one-ulp agreement is guaranteed here
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(seq), rtol=0, atol=1e-6,
            err_msg=f"{spec.describe()} {boundary} T={fuse}")
    else:
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(seq),
            err_msg=f"in-kernel sweep not bit-exact: {spec.describe()} "
                    f"{boundary} T={fuse}")
    ref = x
    for _ in range(steps):
        ref = stencil_ref(ref, spec, boundary=boundary)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               err_msg=f"{spec.describe()} {boundary}")


@pytest.mark.parametrize("boundary", BOUNDARIES)
@pytest.mark.parametrize("name", FAST_SPECS)
def test_inkernel_sweep_bit_exact_fast(name, boundary):
    _check_inkernel(SUITE[name], boundary)


@pytest.mark.slow
@pytest.mark.parametrize("fuse", [2, 3, 4])
@pytest.mark.parametrize("boundary", BOUNDARIES)
@pytest.mark.parametrize("name", sorted(SUITE))
def test_inkernel_sweep_bit_exact_full_suite(name, boundary, fuse):
    _check_inkernel(SUITE[name], boundary, steps=fuse + 1, fuse=fuse)


def test_inkernel_single_scratch_bit_exact_both_modes():
    """scratch="single"|"pingpong" are the same arithmetic (each step's
    input is a materialized value before write-back), so both must be
    bit-exact against the sequential per-step reference, the single-buffer
    variant must halve the modelled scratch residency, and the engine's
    core cache must never alias the two compiled variants."""
    spec = SUITE["star2d_r2"]
    rng = np.random.default_rng(21)
    x = jnp.asarray(rng.normal(size=(40, 40)), jnp.float32)
    outs = {}
    for scratch in ("pingpong", "single"):
        eng = StencilEngine(spec, backend="pallas", block=(16, 16),
                            boundary="periodic", scratch=scratch)
        outs[scratch] = eng.sweep(x, 4, fuse=2, strategy="inkernel")
        seq = _evolve_ref(eng, x, 4, "periodic")
        np.testing.assert_array_equal(np.asarray(outs[scratch]),
                                      np.asarray(seq), err_msg=scratch)
        # per-call override keys separately from the engine default
        assert (2, scratch) in eng._inkernel_cores
        eng.inkernel_core(2, "single")
        assert (2, "single") in eng._inkernel_cores
    np.testing.assert_array_equal(np.asarray(outs["pingpong"]),
                                  np.asarray(outs["single"]))
    # modelled residency: one buffer instead of two
    from repro.core import matrixization as mx
    pp = mx.inkernel_vmem_bytes((64, 128), 4, 2)
    single = mx.inkernel_vmem_bytes((64, 128), 4, 2, scratch="single")
    buf = 4 * float(np.prod([b + 2 * 3 * 2 for b in (64, 128)]))
    assert pp - single == pytest.approx(buf)
    with pytest.raises(ValueError):
        StencilEngine(spec, backend="pallas", scratch="bogus")


def test_unknown_chunk_strategy_raises_not_keyerror():
    """A bogus strategy string (e.g. a hand-edited plan) must fail with a
    clear ValueError at the chunk gate, not silently run operator fusion
    or surface a downstream KeyError."""
    import dataclasses
    eng = StencilEngine(SUITE["box2d_r1"], backend="pallas", block=(16, 16),
                        boundary="periodic")
    with pytest.raises(ValueError, match="fuse strategy"):
        eng._apply_chunk(jnp.ones((32, 32), jnp.float32), 2, "bogus")
    prob = api.StencilProblem(SUITE["box2d_r1"], (32, 32),
                              boundary="periodic", steps=4)
    p = api.plan(prob, fuse=2, backends=["pallas"])
    bad = dataclasses.replace(p, fuse_strategy="bogus")
    with pytest.raises(ValueError, match="fuse strategy"):
        api.compile(bad)


def test_inkernel_equals_operator_fusion_values():
    """Both strategies advance the same evolution (allclose — the operator
    strategy rounds differently by construction)."""
    spec = SUITE["star2d_r2"]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    eng = StencilEngine(spec, backend="pallas", block=(16, 16),
                        boundary="periodic")
    ink = eng.sweep(x, 4, fuse=2, strategy="inkernel")
    op = eng.sweep(x, 4, fuse=2, strategy="operator")
    np.testing.assert_allclose(np.asarray(ink), np.asarray(op), atol=1e-4)


def test_inkernel_batched_leading_axes():
    spec = ss.star(2, 1, seed=3)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(2, 20, 20)), jnp.float32)
    eng = StencilEngine(spec, backend="pallas", block=(8, 8),
                        boundary="zero")
    out = eng.sweep(x, 4, fuse=2, strategy="inkernel")
    ref = x
    for _ in range(4):
        ref = stencil_ref(ref, spec, boundary="zero")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_inkernel_requires_sweep_builder():
    eng = StencilEngine(ss.box(2, 1, seed=0), backend="jnp",
                        boundary="periodic")
    assert not eng.supports_inkernel
    x = jnp.ones((16, 16), jnp.float32)
    with pytest.raises(ValueError):
        eng.sweep(x, 4, fuse=2, strategy="inkernel")
    # and "auto" degrades to operator instead of raising
    assert eng._resolve(4, 2, "auto") == (2, "operator")
    with pytest.raises(ValueError):
        eng.sweep(x, 4, fuse=2, strategy="bogus")


def test_engine_auto_strategy_follows_the_roofline_model():
    """The strategy chooser must track where each strategy actually wins:
    a star's per-step cover stays sparse while its fused operator densifies
    to a full box (in-kernel wins), whereas a 2-D box's fused cover is only
    (2Tr+1) lines vs the in-kernel T*(2r+1) — operator fusion stays
    cheaper there.  _resolve_strategy follows temporal.choose_fuse_depth
    in both regimes."""
    star = ss.star(2, 2, seed=1)
    eng_star = StencilEngine(star, backend="pallas", block=(128, 128),
                             boundary="periodic")
    dec = temporal.choose_fuse_depth(star, 3, (128, 128), max_depth=3,
                                     strategies=temporal.FUSE_STRATEGIES)
    assert eng_star._resolve(3, 3, "auto") == (3, dec.candidate(3).strategy)
    assert dec.candidate(3).strategy == "inkernel"
    box = ss.box(2, 2, seed=1)
    eng_box = StencilEngine(box, backend="pallas", block=(16, 16),
                            boundary="periodic")
    assert eng_box._resolve(3, 3, "auto") == (3, "operator")
    # a pinned strategy restricts the DEPTH search too: fuse="auto" with
    # strategy="operator" must pick the operator-optimal depth, not the
    # depth the joint search would choose for inkernel
    d_op, s_op = eng_star._resolve(8, "auto", "operator")
    dec_op = temporal.choose_fuse_depth(star, 8, (128, 128),
                                        strategies=("operator",))
    assert (d_op, s_op) == (dec_op.depth, "operator")
    d_ink, s_ink = eng_star._resolve(8, "auto", "inkernel")
    dec_ink = temporal.choose_fuse_depth(star, 8, (128, 128),
                                         strategies=("inkernel",))
    assert (d_ink, s_ink) == (dec_ink.depth, "inkernel")


def test_inkernel_flops_model_linear_in_t():
    """The cost helpers carry the headline trade: in-kernel flops grow
    ~linearly with T (same per-step cover each step) while operator fusion
    densifies — a fused star loses its star structure entirely, and 3-D
    covers grow as (2Tr+1)^2 lines vs the in-kernel T*(2r+1)^2."""
    from repro.core import coefficient_lines as cl
    from repro.core import matrixization as mx
    from repro.core.engine import choose_cover
    block2, block3 = (128, 128), (64, 64, 64)
    for spec, block in ((ss.star(2, 2, seed=0), block2),
                        (ss.box(3, 2, seed=0), block3),
                        (ss.star(3, 3, seed=0), block3)):
        _, cover = choose_cover(spec, block[0])
        base = mx.mxu_flops(cover, block)
        for t in (2, 4):
            ink = mx.inkernel_mxu_flops(cover, block, t)
            fspec = temporal.fuse_steps(spec, t)
            fused_opts = ("parallel", "minimal") if spec.ndim == 2 \
                else ("parallel",)
            op = min(mx.mxu_flops(cl.make_cover(fspec, o), block)
                     for o in fused_opts)
            assert ink < op, (spec.describe(), t, ink, op)
            assert ink < 2.0 * t * base      # linear-in-T with halo slack
            # traffic identical between the strategies
            assert mx.inkernel_hbm_bytes(block, t, spec.order) == \
                mx.block_hbm_bytes(block, t * spec.order)
    assert temporal.inkernel_traffic_ratio(4) == 0.25
    # VMEM residency grows with the slab depth and gates the planner
    assert mx.inkernel_vmem_bytes(block2, 4, 2) > \
        mx.inkernel_vmem_bytes(block2, 2, 2)


# ---------------------------------------------------------------------------
# Planner integration
# ---------------------------------------------------------------------------

def test_plan_selects_inkernel_with_strictly_lower_cost():
    """Acceptance: on high-order/3D PAPER_SUITE cells the planner picks
    fuse_strategy="inkernel" at depth >= 2 with a strictly lower modelled
    cost than the best operator-fusion candidate."""
    wins = []
    for name in ("star2d_r2", "box3d_r2", "star3d_r3"):
        spec = SUITE[name]
        grid = (256, 256) if spec.ndim == 2 else (64, 64, 64)
        prob = api.StencilProblem(spec, grid, boundary="periodic", steps=16)
        p = api.plan(prob)
        best_op = min(c.t_per_step for c in p.candidates
                      if c.strategy == "operator")
        assert p.fuse_strategy == "inkernel" and p.fuse_depth >= 2, name
        assert p.chosen().t_per_step < best_op, name
        wins.append(name)
    assert wins


def test_plan_strategy_pin_and_round_trip():
    prob = api.StencilProblem(SUITE["box2d_r1"], (64, 64),
                              boundary="periodic", steps=8)
    p_op = api.plan(prob, fuse_strategy="operator")
    assert all(c.strategy == "operator" for c in p_op.candidates)
    p_ink = api.plan(prob, fuse=2, fuse_strategy="inkernel")
    assert p_ink.fuse_strategy == "inkernel"
    assert p_ink.backend == "pallas"  # only backend with a sweep_builder
    assert all(c.strategy == "inkernel" for c in p_ink.candidates
               if c.depth > 1)
    # a pinned-inkernel search still plans when only depth 1 is feasible
    # (a chunk of one step has no strategy), instead of erroring opaquely
    p1 = api.plan(api.StencilProblem(SUITE["box2d_r1"], (64, 64),
                                     boundary="periodic", steps=1),
                  fuse_strategy="inkernel")
    assert p1.fuse_depth == 1 and p1.fuse_strategy == "operator"
    # inkernel rows keep the BASE cover and the plan records it as both
    # option and base_option (the chunk re-applies it per step)
    assert p_ink.option == p_ink.base_option
    q = api.ExecutionPlan.from_json(p_ink.to_json())
    assert q == p_ink and q.fuse_strategy == "inkernel"
    with pytest.raises(ValueError):
        api.plan(prob, fuse_strategy="bogus")
    with pytest.raises(ValueError):  # no backend can execute it
        api.plan(prob, fuse_strategy="inkernel", backends=["jnp"])


def test_plan_inkernel_vmem_pruning():
    """Deep slabs must fit VMEM: a depth that blows the residency budget
    (slab + double-buffered scratch + every step's stacked Toeplitz
    operators) keeps no inkernel candidate at the offending block."""
    from repro.core import coefficient_lines as cl
    from repro.core import matrixization as mx
    from repro.core.planner import _VMEM_BUDGET
    spec = ss.box(2, 3, seed=2)
    prob = api.StencilProblem(spec, (2048, 2048), boundary="periodic",
                              steps=32)
    p = api.plan(prob, max_depth=4)
    for c in p.candidates:
        if c.strategy == "inkernel":
            cover = cl.make_cover(spec, c.option)
            assert mx.inkernel_vmem_bytes(c.block, c.depth, spec.order,
                                          prob.dtype_bytes,
                                          cover=cover) <= _VMEM_BUDGET
    # the operator term matters: it grows the bound beyond the tile model
    cover = cl.make_cover(spec, "parallel")
    assert mx.inkernel_vmem_bytes((512, 256), 4, spec.order, cover=cover) > \
        mx.inkernel_vmem_bytes((512, 256), 4, spec.order)


def test_compile_inkernel_plan_matches_sequential():
    spec = SUITE["box2d_r2"]
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(48, 48)), jnp.float32)
    for boundary in ("periodic", "zero"):
        prob = api.StencilProblem(spec, (48, 48), boundary=boundary, steps=5)
        p = api.plan(prob, fuse=2, fuse_strategy="inkernel")
        assert p.fuse_schedule == (2, 2, 1)
        run = api.compile(p)
        ref = x
        for _ in range(5):
            ref = stencil_ref(ref, spec, boundary=boundary)
        np.testing.assert_allclose(np.asarray(run(x)), np.asarray(ref),
                                   atol=1e-4, err_msg=boundary)
        f = jax.jit(run.fn)
        f(x), f(x)
        assert f._cache_size() == 1, "inkernel compile retraced"


def test_sweep_fn_inkernel_is_jit_safe():
    spec = ss.box(2, 1, seed=0)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(24, 24)), jnp.float32)
    eng = StencilEngine(spec, backend="pallas", block=(8, 8),
                        boundary="periodic")
    fn = eng.sweep_fn(6, fuse=3, grid=(24, 24), strategy="inkernel")
    # the core cache keys (depth, scratch policy) — everything that
    # changes the compiled core
    assert (3, "pingpong") in eng._inkernel_cores, \
        "inkernel core was not pre-built"
    f = jax.jit(fn)
    out = f(x)
    f(x), f(x)
    assert f._cache_size() == 1
    ref = x
    for _ in range(6):
        ref = stencil_ref(ref, spec, boundary="periodic")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# ---------------------------------------------------------------------------
# Calibration integration: per-(backend, strategy) factors
# ---------------------------------------------------------------------------

def test_calibrate_measures_inkernel_factors_separately():
    from repro.launch.calibrate import calibrate, factor_key
    assert factor_key("pallas") == "pallas"
    assert factor_key("pallas", "inkernel") == "pallas:inkernel"
    prob = api.StencilProblem(SUITE["box2d_r1"], (32, 32),
                              boundary="periodic", steps=4)
    rec = calibrate(prob, top_k=2, backends=["pallas"], fuse=2,
                    fuse_strategy="inkernel")
    assert "pallas:inkernel" in rec.compute
    assert all(m.strategy == "inkernel" for m in rec.measurements)
    again = api.CalibrationRecord.from_json(rec.to_json())
    assert again == rec
    # the factors feed back into the matching rows only
    p = api.plan(prob, fuse=2, backends=["pallas"], calibration=rec)
    for c in p.candidates:
        expect = (rec.traffic["pallas:inkernel"]
                  if c.strategy == "inkernel" else 1.0)
        uncal = api.candidate_cost(prob, c.depth, c.option, c.backend,
                                   block=c.block, strategy=c.strategy)
        assert c.t_traffic == pytest.approx(uncal.t_traffic * expect)
