"""Batched sweep execution: the batch axis folded into the MXU
contractions, end to end.

The acceptance bar is BIT-exactness against a ``jax.vmap`` of the
single-state ``sweep_fn``: folding B states into one kernel instance
issues the SAME per-state banded-Toeplitz contractions (the batch rides
the slab operand of each ``dot_general``; the band operand is shared), so
the batched output must equal the vmapped per-state reference to the last
bit.  The structural claim is checked on the jaxpr: the per-axis
``dot_general`` count does NOT grow with B.  The cost-model claim —
batching fills the MXU rows a small grid leaves idle and amortizes the
per-chunk dispatch overhead, so modelled per-STATE cost falls with B —
is asserted over the PAPER_SUITE (the BENCH_serve.json acceptance
criterion, 7/13 cells minimum).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from parity import assert_sweep_parity, parity_grid
from repro import api
from repro.core import matrixization as mx
from repro.core import stencil_spec as ss
from repro.core.engine import StencilEngine
from repro.kernels import ops

SUITE = ss.PAPER_SUITE()
FAST_SPECS = ["box2d_r1", "star2d_r2", "diag2d_r1", "box3d_r1", "star3d_r1"]
BATCHES = [1, 3, 8]
STRATEGIES = ("operator", "inkernel")


def _engine_for(spec, boundary):
    block = (16, 16) if spec.ndim == 2 else (4, 8, 8)
    return StencilEngine(spec, backend="pallas", block=block,
                         boundary=boundary)


def _grid_for(spec, steps=4):
    return parity_grid(spec, steps)


def _check_batched_parity(spec, boundary, batch, strategy, steps=4, fuse=2):
    # the shared harness does both bars: bit-exact vs jax.vmap of the same
    # sweep closure, and atol=1e-4 vs the iterated gather oracle
    assert_sweep_parity(spec, boundary, strategy, fuse, batch, steps=steps,
                        seed=batch * 10 + steps)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("batch", BATCHES)
@pytest.mark.parametrize("name", FAST_SPECS)
def test_batched_sweep_bit_exact_vs_vmap_fast(name, batch, strategy):
    _check_batched_parity(SUITE[name], "periodic", batch, strategy)


@pytest.mark.slow
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("boundary", ("valid", "zero", "periodic"))
@pytest.mark.parametrize("name", sorted(SUITE))
def test_batched_sweep_bit_exact_full_suite(name, boundary, strategy):
    for batch in BATCHES:
        _check_batched_parity(SUITE[name], boundary, batch, strategy)


def test_batched_zero_boundary_strips():
    """The Dirichlet-0 strip splice must stay per-step-exact per state."""
    for strategy in STRATEGIES:
        _check_batched_parity(SUITE["star2d_r2"], "zero", 3, strategy)


# ---------------------------------------------------------------------------
# Structure: bands shared, batch folded — dots do not grow with B
# ---------------------------------------------------------------------------

def _dot_count(fn, *args):
    return str(jax.make_jaxpr(fn)(*args)).count("dot_general")


@pytest.mark.parametrize("name", ["box2d_r1", "star2d_r2", "box3d_r1"])
def test_per_axis_dot_count_independent_of_batch(name):
    spec = SUITE[name]
    grid = _grid_for(spec)

    def single_step(b):
        x = jnp.zeros((b,) + grid, jnp.float32)
        return _dot_count(lambda a: ops.stencil_matrixized(
            a, spec=spec, boundary="periodic"), x)

    def sweep(b):
        x = jnp.zeros((b,) + grid, jnp.float32)
        return _dot_count(lambda a: ops.stencil_sweep_matrixized(
            a, spec=spec, steps=3, boundary="periodic"), x)

    assert single_step(1) == single_step(8) > 0
    assert sweep(1) == sweep(8) > 0
    # vmapping the same call instead would NOT change the count either
    # (jax batches dots), so also pin the absolute structure: one dot per
    # axis group per step (the wrappers' default is the parallel cover)
    from repro.core import coefficient_lines as cl
    cover = cl.make_cover(spec, "parallel")
    axes = {line.axis for line in cover.lines
            if not line.is_diagonal and line.nnz > 1}
    assert single_step(8) == len(axes)
    assert sweep(8) == 3 * len(axes)


def test_empty_batch_returns_empty_like_the_old_vmap_path():
    spec = SUITE["box2d_r1"]
    x = jnp.zeros((0, 12, 12), jnp.float32)
    out = ops.stencil_matrixized(x, spec=spec, boundary="periodic")
    assert out.shape == (0, 12, 12) and out.dtype == x.dtype
    out = ops.stencil_sweep_matrixized(x, spec=spec, steps=2,
                                       boundary="periodic")
    assert out.shape == (0, 12, 12)


def test_oversized_batch_folds_in_vmem_feasible_chunks():
    """A pinned block that is VMEM-feasible per state must stay
    executable (and correct) at ANY batch: the wrappers split the fold
    into feasible sub-batches instead of one oversized instance."""
    from repro.kernels.ops import _feasible_fold
    spec = SUITE["box2d_r1"]
    rng = np.random.default_rng(17)
    # (256, 256) f32 tile ~0.5 MB haloed/state: 64 states blow the 8 MB
    # budget in one instance
    x = jnp.asarray(rng.normal(size=(64, 256, 256)), jnp.float32)
    chunk = _feasible_fold(64, lambda c: mx.batched_vmem_bytes(
        (256, 256), spec.order, 4, c))
    assert 1 <= chunk < 64
    out = ops.stencil_matrixized(x, spec=spec, block=(256, 256),
                                 boundary="periodic")
    fn = lambda a: ops.stencil_matrixized(a, spec=spec, block=(256, 256),
                                          boundary="periodic")
    np.testing.assert_array_equal(np.asarray(out[:2]),
                                  np.asarray(jax.vmap(fn)(x[:2])))
    # a single over-budget state stays exactly as feasible as pre-batching
    assert _feasible_fold(4, lambda c: float("inf")) == 1


def test_batched_single_step_matches_vmap_bit_exact():
    spec = SUITE["star2d_r2"]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 40, 40)), jnp.float32)
    fn = lambda a: ops.stencil_matrixized(a, spec=spec, boundary="periodic")
    np.testing.assert_array_equal(np.asarray(fn(x)),
                                  np.asarray(jax.vmap(fn)(x)))


# ---------------------------------------------------------------------------
# dtype: bf16 states through the batched f32-accumulating kernel
# ---------------------------------------------------------------------------

def test_batched_bf16_vs_f32_tolerance():
    """bf16 inputs ride the same batched kernel (f32 accumulation inside),
    so the batched bf16 sweep must track the f32 one to bf16 resolution
    and stay bit-exact against its own vmapped reference."""
    spec = SUITE["box2d_r1"]
    rng = np.random.default_rng(11)
    xf = jnp.asarray(rng.normal(size=(4, 40, 40)), jnp.float32)
    xb = xf.astype(jnp.bfloat16)
    eng = _engine_for(spec, "periodic")
    fn = eng.sweep_fn(4, fuse=2, grid=(40, 40))
    out_b, out_f = fn(xb), fn(xf)
    assert out_b.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out_b, np.float32),
                                  np.asarray(jax.vmap(fn)(xb), np.float32))
    # bf16 has ~3 decimal digits; the evolution is contractive (weights
    # sum to 1) so absolute tolerance at bf16 epsilon scale is the bar
    np.testing.assert_allclose(np.asarray(out_b, np.float32),
                               np.asarray(out_f), atol=0.05)


# ---------------------------------------------------------------------------
# Planner integration: batch is a first-class, planner-visible dimension
# ---------------------------------------------------------------------------

def test_problem_batch_validation_and_round_trip():
    with pytest.raises(ValueError):
        api.StencilProblem(SUITE["box2d_r1"], (32, 32), batch=0)
    prob = api.StencilProblem(SUITE["box2d_r1"], (32, 32), steps=4, batch=8)
    assert prob.to_dict()["batch"] == 8
    p = api.plan(prob, backends=["jnp"])
    assert p.batch == 8
    assert all(c.batch == 8 for c in p.candidates)
    q = api.ExecutionPlan.from_json(p.to_json())
    assert q == p and q.batch == 8
    assert "batch" in p.explain()


def test_modelled_per_state_cost_falls_with_batch():
    """Acceptance: per-state modelled cost at B=8 strictly below B=1 on
    >= 7 of 13 PAPER_SUITE cells (t_per_step is already per state)."""
    wins = []
    for name in sorted(SUITE):
        spec = SUITE[name]
        grid = (256, 256) if spec.ndim == 2 else (64, 64, 64)
        per_state = {}
        for b in (1, 8):
            prob = api.StencilProblem(spec, grid, boundary="periodic",
                                      steps=16, batch=b)
            per_state[b] = api.plan(prob).chosen().t_per_step
        if per_state[8] < per_state[1]:
            wins.append(name)
    assert len(wins) >= 7, f"only {len(wins)}/13 cells improved: {wins}"


def test_batched_cost_helpers_reduce_to_legacy_at_batch_one():
    from repro.core import coefficient_lines as cl
    from repro.core.engine import choose_cover
    spec = SUITE["star2d_r2"]
    block = (64, 128)
    _, cover = choose_cover(spec, block[0])
    assert mx.batched_mxu_flops(cover, block, 1) == mx.mxu_flops(cover, block)
    assert mx.batched_inkernel_mxu_flops(cover, block, 3, 1) == \
        mx.inkernel_mxu_flops(cover, block, 3)
    assert mx.batched_hbm_bytes(block, 2, 4, 1) == mx.block_hbm_bytes(
        block, 2, 4)
    # per-state flops strictly improve (the M-fill) while traffic is linear
    assert mx.batched_mxu_flops(cover, block, 8) < \
        8 * mx.batched_mxu_flops(cover, block, 1)
    assert mx.batched_hbm_bytes(block, 2, 4, 8) == \
        8 * mx.batched_hbm_bytes(block, 2, 4, 1)
    # batched VMEM residency gates the block search
    assert mx.batched_vmem_bytes(block, 2, 4, 8) == \
        8 * mx.batched_vmem_bytes(block, 2, 4, 1)


def test_compile_batched_plan_matches_vmapped_compile():
    spec = SUITE["box2d_r2"]
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(3, 40, 40)), jnp.float32)
    for boundary in ("periodic", "zero"):
        prob1 = api.StencilProblem(spec, (40, 40), boundary=boundary,
                                   steps=5)
        prob3 = api.StencilProblem(spec, (40, 40), boundary=boundary,
                                   steps=5, batch=3)
        run1 = api.compile(api.plan(prob1, fuse=2, backends=["pallas"],
                                    block=(16, 16)))
        run3 = api.compile(api.plan(prob3, fuse=2, backends=["pallas"],
                                    block=(16, 16)))
        np.testing.assert_array_equal(np.asarray(run3(x)),
                                      np.asarray(jax.vmap(run1.fn)(x)),
                                      err_msg=boundary)
        # a batched plan rejects un-batched input
        with pytest.raises(ValueError):
            run3(x[0])
    f = jax.jit(run3.fn)
    f(x), f(x)
    assert f._cache_size() == 1, "batched compile retraced"


def test_batched_inkernel_vmem_gate_prunes_by_batch():
    """A batch that blows the in-kernel VMEM residency keeps no inkernel
    candidate at the offending (block, depth)."""
    from repro.core import coefficient_lines as cl
    from repro.core.planner import _VMEM_BUDGET
    spec = SUITE["box2d_r3"]
    prob = api.StencilProblem(spec, (2048, 2048), boundary="periodic",
                              steps=32, batch=8)
    p = api.plan(prob, max_depth=4)
    for c in p.candidates:
        if c.strategy == "inkernel":
            cover = cl.make_cover(spec, c.option)
            assert mx.inkernel_vmem_bytes(c.block, c.depth, spec.order,
                                          prob.dtype_bytes, cover=cover,
                                          batch=8) <= _VMEM_BUDGET
