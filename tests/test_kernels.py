"""Per-kernel allclose sweeps: Pallas (interpret) vs pure-jnp oracles,
across shapes, dtypes, covers, blocks; plus gradient checks."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import stencil_spec as ss
from repro.core import coefficient_lines as cl
from repro.kernels import ops as kops
from repro.kernels.ref import stencil_ref, banded_mixer_ref

from prop import prop_cases


@pytest.mark.parametrize("name,spec", list(ss.PAPER_SUITE().items()))
def test_kernel_vs_oracle_paper_suite(name, spec):
    rng = np.random.default_rng(11)
    shape = (34,) * spec.ndim if spec.ndim == 2 else (10, 14, 18)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    ref = stencil_ref(x, spec)
    block = (16, 16) if spec.ndim == 2 else (4, 8, 8)
    out = kops.stencil_matrixized(x, spec=spec, block=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@prop_cases(n=20, seed=13)
def test_kernel_shape_dtype_sweep(draw):
    ndim = draw.choice([2, 3])
    r = draw.int(1, 2)
    shape_kind = draw.choice(["box", "star"])
    spec = (ss.box if shape_kind == "box" else ss.star)(ndim, r, seed=draw.int(0, 99))
    dims = tuple(draw.int(2 * r + 3, 30) for _ in range(ndim)) if ndim == 2 \
        else tuple(draw.int(2 * r + 3, 14) for _ in range(ndim))
    dtype = draw.choice([jnp.float32, jnp.bfloat16])
    x = jnp.asarray(draw.normal(dims), dtype)
    block = tuple(draw.choice([4, 8, 16]) for _ in range(ndim))
    opt = draw.choice(["parallel"] + (["orthogonal"] if shape_kind == "star" else []))
    out = kops.stencil_matrixized(x, spec=spec, cover=cl.make_cover(spec, opt),
                                  block=block)
    ref = stencil_ref(x, spec)
    atol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)
    assert out.dtype == x.dtype


@prop_cases(n=20, seed=17)
def test_banded_mixer_sweep(draw):
    t = draw.int(5, 70)
    d = draw.int(3, 40)
    w = draw.int(1, 5)
    depthwise = draw.bool()
    lead = draw.choice([(), (2,), (2, 3)])
    x = jnp.asarray(draw.normal(lead + (t, d)), jnp.float32)
    band = jnp.asarray(draw.normal((w, d) if depthwise else (w,)), jnp.float32)
    y = kops.banded_mix(x, band, 16, 16)
    if depthwise:
        acc = None
        for s in range(w):
            sh = jnp.pad(x, [(0, 0)] * len(lead) + [(s, 0), (0, 0)])[..., :t, :]
            term = band[s][None, :] * sh
            acc = term if acc is None else acc + term
        ref = acc
    else:
        ref = banded_mixer_ref(x, band)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4)


def test_banded_mixer_grads_match_autodiff():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 33, 20)), jnp.float32)
    band = jnp.asarray([0.6, 0.25, 0.15], jnp.float32)

    def loss_k(x, b):
        return jnp.sum(jnp.sin(kops.banded_mix(x, b, 16, 16)))

    def loss_r(x, b):
        return jnp.sum(jnp.sin(banded_mixer_ref(x, b)))

    gk = jax.grad(loss_k, argnums=(0, 1))(x, band)
    gr = jax.grad(loss_r, argnums=(0, 1))(x, band)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gr[0]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gr[1]), atol=1e-3)


def test_stencil_vjp_learnable_coeffs():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(18, 18)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(3, 3)), jnp.float32)

    def loss_k(x, c):
        return jnp.sum(jnp.cos(kops.stencil_apply_vjp(x, c)))

    def loss_manual(x, c):
        acc = None
        for u in range(3):
            for v in range(3):
                t = c[u, v] * x[u:u + 16, v:v + 16]
                acc = t if acc is None else acc + t
        return jnp.sum(jnp.cos(acc))

    gk = jax.grad(loss_k, argnums=(0, 1))(x, c)
    gm = jax.grad(loss_manual, argnums=(0, 1))(x, c)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gm[0]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gk[1]), np.asarray(gm[1]), atol=1e-3)


def test_line_batched_contraction_one_dot_per_axis():
    """Paper §4.3 input-vector sharing: all same-axis Toeplitz bands stack
    into ONE matrix, so the kernel issues one dot_general per axis instead
    of one per line — the jaxpr dot count drops from L (5 lines for the
    r=2 box parallel cover) to 1 while parity holds."""
    spec = ss.box(2, 2, seed=7)
    cover = cl.make_cover(spec, "parallel")
    multi_tap_lines = sum(1 for l in cover.lines if l.nnz > 1)
    assert multi_tap_lines == 5  # the pre-batching dot count

    def fn(x):
        return kops.stencil_matrixized(x, spec=spec, cover=cover,
                                       block=(16, 16))

    rng = np.random.default_rng(21)
    x = jnp.asarray(rng.normal(size=(36, 36)), jnp.float32)
    n_dots = str(jax.make_jaxpr(fn)(x)).count("dot_general")
    assert n_dots == 1, f"expected 1 batched dot for 1 line axis, got {n_dots}"
    np.testing.assert_allclose(np.asarray(fn(x)),
                               np.asarray(stencil_ref(x, spec)), atol=2e-5)

    # a 3-D star's orthogonal cover has one line per axis: 3 dots, one each
    spec3 = ss.star(3, 1, seed=3)
    cover3 = cl.make_cover(spec3, "orthogonal")
    x3 = jnp.asarray(rng.normal(size=(10, 12, 14)), jnp.float32)

    def fn3(x):
        return kops.stencil_matrixized(x, spec=spec3, cover=cover3,
                                       block=(4, 8, 8))

    assert str(jax.make_jaxpr(fn3)(x3)).count("dot_general") == 3
    np.testing.assert_allclose(np.asarray(fn3(x3)),
                               np.asarray(stencil_ref(x3, spec3)), atol=2e-5)


def test_kernel_nonmultiple_shapes_padding():
    spec = ss.box(2, 1, seed=4)
    rng = np.random.default_rng(6)
    for shape in [(17, 23), (31, 18), (19, 19)]:
        x = jnp.asarray(rng.normal(size=shape), jnp.float32)
        out = kops.stencil_matrixized(x, spec=spec, block=(16, 16))
        ref = stencil_ref(x, spec)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_kv_scan_attention_path_matches():
    """The online-softmax KV-scan alternative (EXPERIMENTS §Perf iter 3B)
    stays correct even though the dense-chunk path is the default."""
    from repro.models.attention_chunked import chunked_attention, _attn_block
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 300, 8, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 300, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 300, 2, 16)), jnp.float32)
    pos = jnp.arange(300)
    out = chunked_attention(q, k, v, q_positions=pos, k_positions=pos,
                            q_chunk=128, kv_scan=True)
    ref = _attn_block(q, k, v, pos, pos, True, None, None, None, None,
                      4, 1.0 / 4.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
