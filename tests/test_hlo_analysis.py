"""Loop-aware HLO analyzer: exact dot flops under scan, nesting, trip
counts, slice-aware traffic."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_equal_unrolled():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(8):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.bfloat16)
    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.bfloat16)
    a_s = analyze_hlo(_compile(scanned, x, ws).as_text())
    a_u = analyze_hlo(_compile(unrolled, x, ws).as_text())
    assert a_s.dot_flops == a_u.dot_flops == 8 * 2 * 64 ** 3
    assert 8 in a_s.while_trips.values()


def test_nested_scan_multiplies():
    def nested(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    a = analyze_hlo(_compile(nested, x, ws).as_text())
    assert a.dot_flops == 5 * 3 * 2 * 32 ** 3


def test_slice_aware_traffic_not_quadratic_in_stack():
    """Scanning slices of a stacked buffer must not count the full stack
    per iteration."""
    def f(x, ws):
        def body(c, w):
            return c + (c @ w), None
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    n_layers = 64
    ws = jax.ShapeDtypeStruct((n_layers, 128, 128), jnp.float32)
    a = analyze_hlo(_compile(f, x, ws).as_text())
    stack_bytes = n_layers * 128 * 128 * 4
    # the stack is read once (sliced per trip) plus the carry's per-trip
    # read/write across a few fusions — NOT trips x stack (64x)
    assert a.traffic_bytes < 16 * stack_bytes, a.traffic_bytes / stack_bytes
    assert a.traffic_bytes < 0.5 * n_layers * stack_bytes


def test_dot_general_contracting_dims():
    def f(a, b):
        return jax.lax.dot_general(a, b, (((2,), (0,)), ((), ())))

    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    an = analyze_hlo(_compile(f, a, b).as_text())
    assert an.dot_flops == 2 * 4 * 8 * 32 * 16
