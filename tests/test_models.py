"""Per-architecture smoke tests (reduced configs): forward shapes, train
step finiteness + improvement, serve consistency, RWKV/SSM recurrence
equivalence, MoE invariants."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import (ARCH_IDS, ModelConfig, MoEConfig, SSMConfig,
                                cells_for, get_config, get_smoke_config)
from repro.launch.input_specs import train_batch_specs, sample_from_specs
from repro.models import transformer as tf
from repro.models import moe as moe_mod
from repro.models import rwkv6 as R
from repro.models import ssm as S
from repro.optim.adamw import adamw
from repro.train.serve_step import make_decode_step, make_prefill
from repro.train.train_step import init_train_state, make_train_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    opt = adamw(lr=1e-3)
    batch = sample_from_specs(train_batch_specs(cfg, 2, 24), cfg, seed=1)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, ce_chunk=8))
    state, m1 = step(state, batch)
    assert np.isfinite(float(m1["loss"]))
    state, m2 = step(state, batch)
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 1.0
    logits, _, _ = tf.forward(state.params, cfg, batch["tokens"],
                              patch_embeds=batch.get("patch_embeds"),
                              cond=batch.get("cond"), mode="train")
    if cfg.num_codebooks:
        assert logits.shape[-1] == cfg.vocab_size
        assert logits.shape[2] == cfg.num_codebooks
    else:
        assert logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serve_consistency(arch):
    cfg = get_smoke_config(arch)
    batch = sample_from_specs(train_batch_specs(cfg, 2, 20), cfg, seed=2)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = batch["tokens"]
    kw = {k: batch[k] for k in ("patch_embeds", "cond") if k in batch}
    prefill = jax.jit(make_prefill(cfg, max_len=24 + (cfg.num_image_tokens or 0)))
    decode = jax.jit(make_decode_step(cfg))
    last_full, _ = prefill(params, toks, **kw)
    n_pre = 12
    pre = toks[..., :n_pre] if cfg.num_codebooks else toks[:, :n_pre]
    rest = toks[..., n_pre:] if cfg.num_codebooks else toks[:, n_pre:]
    last, st = prefill(params, pre, **kw)
    for t in range(rest.shape[-1]):
        tok = rest[..., t:t + 1] if cfg.num_codebooks else rest[:, t:t + 1]
        last, st = decode(params, st, tok, cond=batch.get("cond"))
    np.testing.assert_allclose(np.asarray(last), np.asarray(last_full),
                               atol=5e-5)


def test_full_configs_param_counts():
    """Full configs carry the published scale (sanity order-of-magnitude)."""
    expect = {"yi_6b": (5e9, 8e9), "gemma_2b": (2e9, 3.5e9),
              "tinyllama_1_1b": (0.9e9, 1.4e9), "gemma3_12b": (9e9, 14e9),
              "musicgen_large": (1.5e9, 4.5e9), "rwkv6_1_6b": (1.2e9, 2.2e9),
              "llava_next_34b": (30e9, 38e9), "qwen3_moe_30b_a3b": (28e9, 33e9),
              "granite_moe_3b_a800m": (2.5e9, 4e9), "hymba_1_5b": (1e9, 2e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_cells_for_long_context_rule():
    assert "long_500k" in cells_for("rwkv6_1_6b")
    assert "long_500k" in cells_for("hymba_1_5b")
    assert "long_500k" in cells_for("gemma3_12b")
    assert "long_500k" not in cells_for("yi_6b")
    assert "long_500k" not in cells_for("qwen3_moe_30b_a3b")
    total = sum(len(cells_for(a)) for a in ARCH_IDS)
    assert total == 33  # 40 assignment cells - 7 documented skips


def test_rwkv_chunked_equals_sequential():
    cfg = get_smoke_config("rwkv6_1_6b")
    p = R.init_rwkv_layer(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, cfg.d_model))
    y_chunk, s_chunk = R.rwkv_time_mix(p, x, cfg)
    st = R.init_rwkv_state(2, cfg)
    ys = []
    for t in range(37):
        y, s_new = R.rwkv_time_mix_step(p, x[:, t], cfg, st)
        st = R.RWKVState(s=s_new, x_tm=x[:, t], x_cm=st.x_cm)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_chunk),
                               np.asarray(jnp.stack(ys, 1)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(st.s), atol=1e-4)


def test_ssm_chunked_equals_sequential():
    cfg = get_smoke_config("hymba_1_5b")
    p = S.init_ssm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 41, cfg.d_model))
    y_par, st_par = S.ssm_forward(p, x, cfg)
    st = S.init_ssm_state(2, cfg)
    ys = []
    for t in range(41):
        y, st = S.ssm_step(p, x[:, t], cfg, st)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_par),
                               np.asarray(jnp.stack(ys, 1)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_par.h), np.asarray(st.h), atol=1e-4)


def test_moe_dropless_matches_dense_reference():
    mcfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), 8, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 8))
    out = moe_mod.moe_ffn(p, x, mcfg, dropless=True)
    # dense reference: run every expert on every token, combine by gates
    xt = x.reshape(-1, 8)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, experts = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    y_ref = jnp.zeros_like(xt)
    for e in range(4):
        g = jax.nn.silu(xt @ p["wi_gate"][e]) * (xt @ p["wi_up"][e])
        ye = g @ p["wo"][e]
        w = jnp.where(experts == e, gates, 0.0).sum(-1)
        y_ref = y_ref + w[:, None] * ye
    np.testing.assert_allclose(np.asarray(out.y.reshape(-1, 8)),
                               np.asarray(y_ref), atol=1e-4)
    assert float(out.aux_loss) > 0


def test_moe_capacity_drops_tokens():
    mcfg = MoEConfig(num_experts=2, top_k=1, d_ff_expert=8,
                     capacity_factor=0.5)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), 4, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 4))
    out_cap = moe_mod.moe_ffn(p, x, mcfg)
    out_free = moe_mod.moe_ffn(p, x, mcfg, dropless=True)
    # capacity 0.5 must zero some tokens vs dropless
    diff = np.abs(np.asarray(out_cap.y - out_free.y)).max()
    assert diff > 1e-6


def test_sliding_window_masks_long_range():
    cfg = get_smoke_config("gemma3_12b")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 30), 0, cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 1) % cfg.vocab_size)
    l1, _, _ = tf.forward(params, cfg, t1, mode="train")
    l2, _, _ = tf.forward(params, cfg, t2, mode="train")
    # with window 8 and one global layer per 6, late positions DO see pos 0
    # through the global layer; but a pure-local variant must not:
    import dataclasses
    cfg_local = dataclasses.replace(cfg, local_global_period=0,
                                    num_layers=2, sliding_window=8)
    params_l = tf.init_params(jax.random.PRNGKey(0), cfg_local)
    l1l, _, _ = tf.forward(params_l, cfg_local, t1, mode="train")
    l2l, _, _ = tf.forward(params_l, cfg_local, t2, mode="train")
    np.testing.assert_allclose(np.asarray(l1l[:, -1]), np.asarray(l2l[:, -1]),
                               atol=1e-5)  # pos 0 outside every window
    assert np.abs(np.asarray(l1[:, 8:12]) - np.asarray(l2[:, 8:12])).max() > 0 \
        or np.abs(np.asarray(l1[:, -1]) - np.asarray(l2[:, -1])).max() > 1e-7
