"""Distributed chaos: the ``dist.*`` fault sites, sharded checkpoints
and reshard-on-failure recovery (DESIGN.md §Robustness, "Distributed
failure ladder").

Everything multi-device runs in subprocesses with 8 fake CPU devices
(the main pytest process stays at 1 device by design — see the dry-run
contract).  Fast single-scenario tests are tier-1; the exhaustive
site x action x seed x mesh-shape matrix is ``slow`` (``make
test-dist-chaos`` / ``make test-all``).
"""
import pytest

from test_multidevice import run_with_devices

# Shared subprocess prelude: a 3-segment mesh-sharded rollout program on
# a 24x24 grid (divisible by every 1-axis mesh in the 8-device ladder).
_PRELUDE = """
import glob, os, tempfile
import numpy as np, jax, jax.numpy as jnp
from repro import api
from repro.launch.mesh import make_mesh
from repro.runtime import chaos
from repro.runtime.fault_tolerance import RestartPolicy
from repro.rollout.program import RolloutProgram, Segment, UpdateOp
from repro.rollout.executor import compile_program, run_checkpointed, shrink_mesh

SPEC = api.box(2, 1, seed=0)
GRID = (24, 24)
X = jnp.asarray(np.random.default_rng(0).normal(size=GRID), jnp.float32)

def program(mesh, grid_axes=("gx", "")):
    prob = api.StencilProblem(SPEC, GRID, boundary="periodic", steps=1,
                              mesh=mesh, grid_axes=grid_axes)
    return RolloutProgram(prob, [
        Segment(2, emit=True),
        Segment(2, UpdateOp("scale", {"factor": 0.5}), emit=True),
        Segment(2, emit=True)])

def bitsame(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))
"""


def test_dist_sites_fire_raise_and_corrupt():
    """The host-side wrapper fires dist.chunk / dist.exchange /
    dist.device; "raise" raises a FaultError carrying the site, "corrupt"
    computes through a poisoned copy then raises (the result is
    discarded), and an ACTIVE-but-idle plan leaves results
    bit-identical."""
    run_with_devices(_PRELUDE + """
prob = api.StencilProblem(SPEC, GRID, boundary="periodic", steps=4,
                          mesh=make_mesh((4,), ("gx",)), grid_axes=("gx", ""))
run = api.compile(api.plan(prob, fuse=2, backends=["jnp"]), mesh=prob.mesh)
y0 = np.asarray(run(X))

plan = chaos.FaultPlan(seed=3).rule("dist.chunk", at=(1,))
try:
    with plan:
        run(X)
    raise SystemExit("dist.chunk never raised")
except chaos.FaultError as e:
    assert e.site == "dist.chunk", e.site
assert plan.fired("dist.chunk") == 1
site, idx, action, ctx = plan.log[0]
assert ctx["devices"] == 4 and ctx["mesh"] == "4", ctx

plan2 = chaos.FaultPlan(seed=3).rule("dist.exchange", at=(0,),
                                     action="corrupt")
try:
    with plan2:
        run(X)
    raise SystemExit("dist.exchange corrupt never surfaced")
except chaos.FaultError as e:
    assert "checksum" in str(e), e

plan3 = chaos.FaultPlan(seed=1).rule("dist.device", rate=0.0)
with plan3:
    y1 = np.asarray(run(X))
assert np.array_equal(y0, y1), "idle plan changed bits"
assert np.array_equal(y0, np.asarray(run(X))), "post-fault call dirty"
""")


def test_ppermute_census_unchanged_by_chaos_wrapper():
    """The chaos wrapper is host-side only: the traced computation —
    counted as ppermutes in the jaxpr — is identical with and without an
    active plan, and matches chunks x sharded-axes x 2."""
    run_with_devices(_PRELUDE + """
prob = api.StencilProblem(SPEC, GRID, boundary="periodic", steps=4,
                          mesh=make_mesh((4,), ("gx",)), grid_axes=("gx", ""))
run = api.compile(api.plan(prob, fuse=2, backends=["jnp"]), mesh=prob.mesh)
n0 = str(jax.make_jaxpr(run.global_fn)(X)).count("ppermute")
with chaos.FaultPlan(seed=9).rule("dist.chunk", rate=1.0, times=0):
    n1 = str(jax.make_jaxpr(run.global_fn)(X)).count("ppermute")
assert n0 == n1, (n0, n1)
assert n0 == 2 * 1 * 2, n0   # 2 fused chunks x 1 sharded axis x 2 dirs
""")


def test_reshard_recovery_bit_exact():
    """The acceptance scenario: a dist.exchange fault storm exhausts
    segment 1's retry budget mid-rollout, the executor reshards 4 -> 2
    devices from the shard checkpoint, and every emit plus the final
    state is BIT-exact vs the fault-free 4-device run (1-axis meshes of
    >= 2 devices are a bit-exact family); the post-reshard checkpoint
    carries the 2-shard layout."""
    run_with_devices(_PRELUDE + """
ref4 = run_checkpointed(compile_program(program(make_mesh((4,), ("gx",))),
                                        backends=["jnp"]), X)
ref2 = run_checkpointed(compile_program(program(make_mesh((2,), ("gx",))),
                                        backends=["jnp"]), X)
for (_, a), (_, b) in zip(ref4.emits, ref2.emits):
    assert bitsame(a, b), "4-dev and 2-dev disagree fault-free"

with tempfile.TemporaryDirectory() as d:
    c4 = compile_program(program(make_mesh((4,), ("gx",))), backends=["jnp"])
    plan = chaos.FaultPlan(seed=5).rule("dist.exchange", at=(1, 2, 3),
                                        match={"chunk": 0})
    with plan:
        res = run_checkpointed(
            c4, X, directory=d,
            restart=RestartPolicy(max_failures=2, backoff_s=0.0))
    assert plan.fired("dist.exchange") == 3
    assert res.attempts == (1, 4, 1), res.attempts
    assert res.recovered == (0, 1, 0), res.recovered
    assert res.resharded == 1, res.resharded
    for (sa, a), (sb, b) in zip(res.emits, ref4.emits):
        assert sa == sb and bitsame(a, b), "reshard broke bit-exactness"
    assert bitsame(res.final, ref4.final)
    last = sorted(glob.glob(os.path.join(d, "step_*")))[-1]
    shards = sorted(os.path.basename(p)
                    for p in glob.glob(os.path.join(last, "shard_*")))
    assert shards == ["shard_0.npz", "shard_1.npz"], shards
""")


def test_torn_shard_write_falls_back_to_previous_checkpoint():
    """A torn single-SHARD write (file truncated, manifest + rename
    completed) is caught by the per-shard manifest digest: restoring the
    torn step raises, and a resume falls back to the newest intact
    checkpoint and recomputes — bit-exact."""
    run_with_devices(_PRELUDE + """
from repro.checkpoint.checkpointer import restore_checkpoint, retained_steps
mesh = make_mesh((4,), ("gx",))
ref = run_checkpointed(compile_program(program(mesh), backends=["jnp"]), X)
with tempfile.TemporaryDirectory() as d:
    c = compile_program(program(mesh), backends=["jnp"])
    # corrupt the SECOND checkpoint write (segment 1's, step 4): with a
    # sharded tree the chaos hook truncates the highest-numbered shard
    plan = chaos.FaultPlan(seed=0).rule("checkpoint.write", at=(1,),
                                        action="corrupt")
    with plan:
        mid = run_checkpointed(c, X, directory=d)
    assert bitsame(mid.final, ref.final)
    assert retained_steps(d) == [2, 4, 6]
    try:
        restore_checkpoint(d, 4, {"state": X})
        raise SystemExit("torn shard restored cleanly")
    except ValueError as e:
        assert "digest" in str(e), e
    # resume=True walks newest-first: step 6 is intact, so a fresh run
    # restores it and returns immediately with the same final state
    c2 = compile_program(program(mesh), backends=["jnp"])
    res = run_checkpointed(c2, X, directory=d)
    assert bitsame(res.final, ref.final)
    assert res.attempts == (0, 0, 0), res.attempts
""")


def test_cache_key_includes_mesh_shape():
    """A reshard is a different executable: problems differing only in
    mesh shape get different cache keys (and both differ from the
    unsharded problem)."""
    run_with_devices(_PRELUDE + """
from repro.core.plan_cache import cache_key
def key(mesh):
    kw = {} if mesh is None else {"mesh": mesh, "grid_axes": ("gx", "")}
    return cache_key(api.StencilProblem(SPEC, GRID, boundary="periodic",
                                        steps=4, **kw))
k4, k2, k0 = key(make_mesh((4,), ("gx",))), key(make_mesh((2,), ("gx",))), key(None)
assert len({k4, k2, k0}) == 3, (k4, k2, k0)
""")


def test_server_eviction_shrinks_group_mesh():
    """The serving mirror: under mesh serving an evicted device SHRINKS
    the shape group's mesh over the survivors (counted in
    stats()["faults"]["mesh_shrinks"]) instead of remapping, and the
    shrunk-mesh results stay bit-exact vs the healthy mesh run."""
    run_with_devices(_PRELUDE + """
from repro.launch.serve_stencil import StencilServer
states = [jnp.asarray(np.random.default_rng(s).normal(size=GRID),
                      jnp.float32) for s in range(3)]
healthy = StencilServer(SPEC, steps=4, backends=["jnp"], max_batch=1,
                        devices=jax.devices(), mesh_shape=(4,))
base = healthy.serve(states)
assert healthy.stats()["meshes"] == {"24x24": "4"}

srv = StencilServer(SPEC, steps=4, backends=["jnp"], max_batch=1,
                    devices=jax.devices(), mesh_shape=(4,), evict_after=3)
plan = chaos.FaultPlan(seed=0).rule("serve.settle", at=(0, 1, 2))
with plan:
    out = srv.serve(states)
st = srv.stats()
assert st["faults"]["evictions"] == 1, st["faults"]
assert st["faults"]["mesh_shrinks"] == 1, st["faults"]
assert st["meshes"] == {"24x24": "2"}, st["meshes"]
for a, b in zip(base, out):
    assert bitsame(a, b), "shrunk-mesh serving broke bit-exactness"

# rollout serving on the mesh books the executor-mirror counters
t = srv.submit_rollout(states[0], [(2, None, True), (2, None, False)])
final = srv.flush()[t]
st = srv.stats()
assert st["faults"]["rollout_attempts"] == 2, st["faults"]
assert st["faults"]["rollout_recovered"] == 0, st["faults"]
""")


@pytest.mark.slow
@pytest.mark.parametrize("mesh_shape,grid_axes", [
    ((4,), ("gx", "")),
    ((2, 2), ("gx", "gy")),
])
def test_dist_fault_matrix(mesh_shape, grid_axes):
    """Exhaustive seeded matrix per mesh shape: site x action x seed,
    random-rate rules.  Every cell must (a) recover within the
    retry + reshard ladder, (b) be deterministic — the same plan seed
    reproduces the identical fire log and identical result bytes — and
    (c) round-trip through FaultPlan.replay()."""
    run_with_devices(_PRELUDE + f"""
MESH_SHAPE, GRID_AXES = {mesh_shape!r}, {grid_axes!r}
ref = run_checkpointed(
    compile_program(program(make_mesh(MESH_SHAPE, ("gx", "gy")[:len(MESH_SHAPE)]),
                            GRID_AXES), backends=["jnp"]), X)

def cell(site, action, seed):
    plan = chaos.FaultPlan(seed=seed).rule(site, rate=0.3, times=3,
                                           action=action)
    mesh = make_mesh(MESH_SHAPE, ("gx", "gy")[:len(MESH_SHAPE)])
    c = compile_program(program(mesh, GRID_AXES), backends=["jnp"])
    with plan:
        res = run_checkpointed(
            c, X, restart=RestartPolicy(max_failures=2, backoff_s=0.0))
    return plan, res

for site in ("dist.exchange", "dist.chunk", "dist.device"):
    for action in ("raise", "corrupt"):
        for seed in (0, 1):
            p1, r1 = cell(site, action, seed)
            p2, r2 = cell(site, action, seed)
            assert p1.log == p2.log, (site, action, seed)
            assert bitsame(r1.final, r2.final), (site, action, seed)
            assert r1.attempts == r2.attempts and \\
                r1.resharded == r2.resharded, (site, action, seed)
            # replay pins the fired indices exactly
            rp = p1.replay()
            mesh = make_mesh(MESH_SHAPE, ("gx", "gy")[:len(MESH_SHAPE)])
            c = compile_program(program(mesh, GRID_AXES), backends=["jnp"])
            with rp:
                r3 = run_checkpointed(
                    c, X,
                    restart=RestartPolicy(max_failures=2, backoff_s=0.0))
            assert rp.log == p1.log, (site, action, seed, rp.log, p1.log)
            assert bitsame(r3.final, r1.final), (site, action, seed)
            if r1.resharded == 0:
                # no topology change: the faulted run matches fault-free
                assert bitsame(r1.final, ref.final), (site, action, seed)
print("matrix OK")
""", timeout=600)
