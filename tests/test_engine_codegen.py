"""Engine backend parity + generated-code correctness + time stepping."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import stencil_spec as ss
from repro.core.codegen import generate_update
from repro.core.engine import StencilEngine
from repro.core.time_stepper import evolve, evolve_until
from repro.kernels.ref import stencil_ref


@pytest.mark.parametrize("backend", ["jnp", "separable", "codegen", "pallas"])
def test_backend_parity(backend):
    spec = ss.star(2, 2, seed=7)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(36, 36)), jnp.float32)
    eng = StencilEngine(spec, option="auto", backend=backend, block=(16, 16))
    np.testing.assert_allclose(np.asarray(eng(x)),
                               np.asarray(stencil_ref(x, spec)), atol=2e-5)


def test_codegen_source_structure():
    spec = ss.star(3, 1, seed=1)
    eng = StencilEngine(spec, option="hybrid", backend="jnp")
    gen = generate_update(eng.plan)
    assert "def stencil_update" in gen.source
    # hybrid: 2r+1 j-lines + 1 k-line with >1 tap each at r=1? lines appear
    assert gen.source.count("# line") == len(eng.plan.cover.lines)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(10, 12, 14)), jnp.float32)
    np.testing.assert_allclose(np.asarray(gen.fn(x)),
                               np.asarray(stencil_ref(x, spec)), atol=2e-5)


def test_diagonal_codegen():
    spec = ss.diagonal(1, seed=5)
    eng = StencilEngine(spec, option="diagonal", backend="codegen")
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(20, 20)), jnp.float32)
    np.testing.assert_allclose(np.asarray(eng(x)),
                               np.asarray(stencil_ref(x, spec)), atol=2e-5)


def test_evolution_conservation_and_convergence():
    spec = ss.box(2, 1, seed=3)  # normalized coefficients (sum=1)
    eng = StencilEngine(spec, boundary="periodic")
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    res = eng.run(x, steps=40)
    assert abs(float(res.mean() - x.mean())) < 1e-5  # mass conservation
    r, snaps = evolve(eng.step_fn(), x, 20, record_every=5)
    assert snaps.shape[0] == 4
    r2 = evolve_until(eng.step_fn(), x, tol=1e-3, max_steps=1000)
    assert float(r2.residual) <= 1e-3
    assert int(r2.steps_run) < 1000
