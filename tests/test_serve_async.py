"""Async continuous-batching server: bit-exactness vs the synchronous
loop, step()-driven bucket formation, latency/deadline tracking,
admission control at the batch-scaled VMEM cliff, deferred-device-error
recovery (cold-executable accounting), and multi-device routing."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro import api
from repro.core import stencil_spec as ss
from repro.core.plan_cache import PlanCache
from repro.kernels.ref import stencil_ref

from test_multidevice import run_with_devices


def _ref(state, spec, steps, boundary="periodic"):
    out = jnp.asarray(state)
    for _ in range(steps):
        out = stencil_ref(out, spec, boundary=boundary)
    return np.asarray(out)


# ---------------------------------------------------------------------------
# Dispatch-mode equivalence
# ---------------------------------------------------------------------------

def test_async_dispatch_bit_exact_vs_sync_on_mixed_stream():
    """The overlapped scheduler is a pure reordering of host work: on the
    same mixed-shape stream it forms the same buckets and returns
    BIT-identical results to the synchronous loop (and both match the
    sequential reference)."""
    spec = ss.star(2, 2, seed=1)
    rng = np.random.default_rng(5)
    shapes = [(32, 32), (24, 24), (32, 32), (32, 32), (24, 24), (32, 32),
              (32, 32)]
    states = [rng.normal(size=s).astype(np.float32) for s in shapes]
    a = api.StencilServer(spec, 3, max_batch=4, backends=["jnp"],
                          async_dispatch=True)
    s_ = api.StencilServer(spec, 3, max_batch=4, backends=["jnp"],
                           async_dispatch=False)
    outs_a, outs_s = a.serve(states), s_.serve(states)
    for state, oa, os_ in zip(states, outs_a, outs_s):
        np.testing.assert_array_equal(np.asarray(oa), np.asarray(os_))
        np.testing.assert_allclose(np.asarray(oa), _ref(state, spec, 3),
                                   atol=1e-4)
    # identical bucket formation, counters and cache traffic
    for srv in (a, s_):
        st = srv.stats()
        assert st["requests"] == 7 and st["batches"] == 3
        assert st["padded_states"] == 0
        assert st["plan_cache"]["misses"] == 3
        assert st["latency"]["count"] == 7


def test_step_admits_newly_submitted_states_between_turns():
    """Continuous batching: a state submitted while a bucket is in flight
    rides the NEXT turn's bucket — two singleton buckets, not one of 2 —
    and results flow through ready()/results()."""
    spec = ss.box(2, 1, seed=0)
    server = api.StencilServer(spec, 2, max_batch=4, backends=["jnp"])
    rng = np.random.default_rng(4)
    s0 = rng.normal(size=(16, 16)).astype(np.float32)
    s1 = rng.normal(size=(16, 16)).astype(np.float32)
    t0 = server.submit(s0)
    assert server.step() == 0            # dispatched, still in flight
    t1 = server.submit(s1)               # admitted into the next turn
    assert server.step() == 1            # settles t0, dispatches t1
    assert server.ready(t0) and not server.ready(t1)
    assert server.step() == 1
    np.testing.assert_allclose(np.asarray(server.results(t0)),
                               _ref(s0, spec, 2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(server.results(t1)),
                               _ref(s1, spec, 2), atol=1e-4)
    assert server.stats()["batches"] == 2
    with pytest.raises(KeyError, match="no claimable result"):
        server.results(t0)               # already claimed
    with pytest.raises(KeyError):
        server.results(999)              # never existed


def test_latency_and_deadline_tracking():
    spec = ss.box(2, 1, seed=0)
    server = api.StencilServer(spec, 2, max_batch=4, backends=["jnp"])
    rng = np.random.default_rng(2)
    states = [rng.normal(size=(16, 16)).astype(np.float32)
              for _ in range(3)]
    server.submit(states[0], deadline_s=0.0)    # every latency > 0: a miss
    server.submit(states[1], deadline_s=1e6)    # never missed
    server.submit(states[2])                    # no deadline: never a miss
    server.flush()
    s = server.stats()
    assert s["deadline_misses"] == 1
    lat = s["latency"]
    assert lat["count"] == 3
    assert 0 < lat["p50_s"] <= lat["p95_s"] <= lat["max_s"]
    assert lat["mean_s"] > 0
    server.reset_stats()
    assert server.stats()["latency"]["count"] == 0


# ---------------------------------------------------------------------------
# Admission control (the batch-scaled VMEM cliff)
# ---------------------------------------------------------------------------

def test_planner_bucket_cliff_query():
    """max_profitable_batch caps the 3-D star at the model grid BELOW
    max_batch (the batch-scaled VMEM pruning makes B=8 a modelled
    per-state loss) while the 2-D box keeps winning to B=8."""
    assert api.serving_buckets(8) == [1, 2, 4, 8]
    assert api.serving_buckets(6) == [1, 2, 4, 6]
    assert api.serving_buckets(1) == [1]
    suite = api.PAPER_SUITE()
    star = api.StencilProblem(suite["star3d_r2"], (64, 64, 64),
                              boundary="periodic", steps=16)
    box = api.StencilProblem(suite["box2d_r1"], (256, 256),
                             boundary="periodic", steps=16)
    curve = api.batch_cost_curve(star, 8)
    assert set(curve) == {1, 2, 4, 8}
    cap = api.max_profitable_batch(star, 8)
    assert cap < 8, curve                  # the cliff caps the bucket
    assert curve[cap] == min(curve.values())
    assert api.max_profitable_batch(box, 8) == 8
    # rtol loosens the cap monotonically; huge rtol admits everything
    assert api.max_profitable_batch(star, 8, rtol=1e9) == 8


def test_server_admission_caps_bucket_formation(monkeypatch):
    """With the cliff query answering 2, five same-shape states form
    3 buckets (2+2+1, no padding) instead of one padded bucket of 8 —
    and the capped stream still matches the uncapped results."""
    monkeypatch.setattr(PlanCache, "bucket_cap",
                        lambda self, problem, max_batch, **kw: 2)
    spec = ss.box(2, 1, seed=0)
    rng = np.random.default_rng(6)
    states = [rng.normal(size=(16, 16)).astype(np.float32)
              for _ in range(5)]
    capped = api.StencilServer(spec, 2, max_batch=8, backends=["jnp"])
    outs = capped.serve(states)
    s = capped.stats()
    assert s["admission"] == {"16x16": 2}
    assert s["batches"] == 3 and s["padded_states"] == 0   # 2+2+1
    assert s["plan_cache"]["misses"] == 2                  # buckets {2, 1}
    free = api.StencilServer(spec, 2, max_batch=8, backends=["jnp"],
                             admission=False)
    outs_free = free.serve(states)
    assert free.stats()["batches"] == 1
    assert free.stats()["padded_states"] == 3              # bucket of 8
    assert free.stats()["admission"] == {"16x16": 8}
    for a, b in zip(outs, outs_free):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# Failure recovery under deferred dispatch
# ---------------------------------------------------------------------------

class _Boom:
    """An unrealized 'result' whose readiness wait raises — the shape of
    a deferred device error under JAX async dispatch."""

    def block_until_ready(self):
        raise RuntimeError("deferred device error")


def test_deferred_device_failure_keeps_executable_cold_and_requeues():
    """A bucket whose device work fails AFTER dispatch: its requests are
    requeued, nothing is double-counted, and — the satellite-2 contract —
    the executable books NO successful call, so the retry's real first
    call is still accounted as compile, not warm, time."""
    spec = ss.box(2, 1, seed=0)
    server = api.StencilServer(spec, 2, max_batch=4, backends=["jnp"],
                               admission=False)
    rng = np.random.default_rng(8)
    states = [rng.normal(size=(16, 16)).astype(np.float32)
              for _ in range(4)]
    tickets = [server.submit(s) for s in states]
    # pre-seed the bucket-4 entry and sabotage its dispatch
    entry = server.cache.get(server._problem((16, 16), 4),
                             backends=["jnp"])
    real_fn = entry.fn
    entry.fn = lambda x: _Boom()
    with pytest.raises(ValueError, match="stay queued"):
        server.flush()
    assert entry.calls == 0 and entry.compile_s == 0.0     # still COLD
    assert not entry.warm
    assert sorted(server.pending_tickets()) == tickets     # nothing lost
    assert server.stats_.batches == 0 and server.stats_.requests == 0
    assert server.stats()["latency"]["count"] == 0
    entry.fn = real_fn
    outs = server.flush()
    assert sorted(outs) == tickets
    for t, state in zip(tickets, states):
        np.testing.assert_allclose(np.asarray(outs[t]),
                                   _ref(state, spec, 2), atol=1e-4)
    # the recovery call was the entry's FIRST success: compile-accounted
    assert entry.calls == 1 and entry.compile_s > 0
    assert entry.wall_s == 0.0
    assert server.stats_.compile_wall_s > 0
    assert server.stats_.warm_states == 0


def test_serve_does_not_drop_recovered_results_of_other_tickets():
    """Satellite-1 regression: results recovered by a later flush for
    tickets serve() does NOT own used to be silently discarded with the
    rest of its claim; they must stay claimable via results()/flush()."""
    spec = ss.box(2, 1, seed=0)
    server = api.StencilServer(spec, 4, boundary="valid", max_batch=4,
                               backends=["jnp"])
    rng = np.random.default_rng(7)
    good_states = [rng.normal(size=(32, 32)).astype(np.float32)
                   for _ in range(2)]
    good = [server.submit(s) for s in good_states]
    bad = server.submit(np.ones((33, 1), np.float32))  # infeasible shape
    with pytest.raises(ValueError, match=str(bad)):
        server.flush()
    assert server.pending_tickets() == [bad]
    assert server.ready(good[0]) and server.ready(good[1])
    server.cancel(bad)
    # serve() on fresh traffic claims only its own ticket...
    outs = server.serve([rng.normal(size=(32, 32)).astype(np.float32)])
    assert len(outs) == 1
    # ...and the recovered results are still claimable afterwards
    assert server.ready(good[0]) and server.ready(good[1])
    np.testing.assert_allclose(np.asarray(server.results(good[0])),
                               _ref(good_states[0], spec, 4,
                                    boundary="valid"), atol=1e-4)
    assert list(server.flush()) == [good[1]]
    assert not server.ready(good[1])


# ---------------------------------------------------------------------------
# Multi-device routing (subprocess: fake CPU devices)
# ---------------------------------------------------------------------------

def test_multi_device_round_robin_shape_groups():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import api
        from repro.core import stencil_spec as ss
        from repro.kernels.ref import stencil_ref

        devices = jax.devices()
        assert len(devices) == 4
        spec = ss.box(2, 1, seed=0)
        server = api.StencilServer(spec, 2, max_batch=4, backends=["jnp"],
                                   devices=devices)
        assert len(server.caches) == 4
        rng = np.random.default_rng(0)
        shapes = [(16, 16), (24, 24), (32, 32)]
        states = [rng.normal(size=shapes[i % 3]).astype(np.float32)
                  for i in range(9)]
        outs = server.serve(states)
        for state, out in zip(states, outs):
            ref = jnp.asarray(state)
            for _ in range(2):
                ref = stencil_ref(ref, spec, boundary="periodic")
            assert float(jnp.abs(out - ref).max()) < 1e-4
        s = server.stats()
        # three shape groups -> three DISTINCT devices, sticky routing
        used = [d for d in s["devices"] if d["batches"]]
        assert len(used) == 3
        assert len({d["device"] for d in used}) == 3
        for d in used:
            assert d["batches"] == 1 and d["states"] == 3
            assert d["plan_cache"]["misses"] == 1
        # merged plan-cache column sums the per-device caches
        assert s["plan_cache"]["misses"] == 3
        server.serve(states)   # warm: same groups, same devices, all hits
        s2 = server.stats()
        assert s2["plan_cache"]["misses"] == 3
        assert s2["plan_cache"]["hits"] == 3
        print("MULTI-DEVICE SERVE OK")
    """, n=4)


# ---------------------------------------------------------------------------
# Bench smoke (the serving benchmark must run end to end on a tiny cell)
# ---------------------------------------------------------------------------

def test_bench_serve_smoke_runs():
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "benchmarks", "bench_serve.py"),
         "--smoke"],
        capture_output=True, text=True, env=env, timeout=420)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "bench-serve smoke OK" in proc.stdout
    assert "admission cap" in proc.stdout


# ---------------------------------------------------------------------------
# Accessor/scheduler races: timeout expiry mid-settle, cancel vs requeue
# ---------------------------------------------------------------------------

def test_results_timeout_expires_mid_settle_then_claims():
    """``results(ticket, timeout_s=...)`` expiring WHILE the ticket's
    bucket is still settling raises ``TimeoutError`` without consuming
    anything; a second blocking claim returns the correct result once
    the (chaos-delayed) settle lands."""
    from repro.runtime import chaos
    spec = ss.box(2, 1, seed=0)
    server = api.StencilServer(spec, 2, max_batch=2, backends=["jnp"])
    rng = np.random.default_rng(0)
    state = rng.normal(size=(16, 16)).astype(np.float32)
    server.serve([state])          # warm: the injected delay dominates
    plan = chaos.FaultPlan(seed=0).rule("serve.settle", action="delay",
                                        delay_s=0.6, at=(0,))
    server.start(poll_s=0.01)
    try:
        with plan:
            t = server.submit(state)
            with pytest.raises(TimeoutError):
                server.results(t, timeout_s=0.05)
            out = server.results(t, timeout_s=30.0)
    finally:
        server.stop()
    assert plan.fired("serve.settle") == 1
    np.testing.assert_allclose(np.asarray(out), _ref(state, spec, 2),
                               atol=1e-4)
    # the expired wait neither lost nor double-claimed the ticket
    with pytest.raises(KeyError):
        server.results(t)


def test_cancel_races_requeued_bucket():
    """A ticket cancelled while its FAILED bucket sits requeued is gone
    for good: the retry bucket re-forms without it, the survivors settle
    with correct values, and the cancelled ticket has no claimable
    result."""
    from repro.runtime import chaos
    spec = ss.box(2, 1, seed=0)
    server = api.StencilServer(
        spec, 2, max_batch=4, backends=["jnp"], admission=False,
        async_dispatch=False,
        restart=api.RestartPolicy(max_failures=3, backoff_s=0.0))
    rng = np.random.default_rng(3)
    states = [rng.normal(size=(16, 16)).astype(np.float32)
              for _ in range(3)]
    tickets = [server.submit(s) for s in states]
    plan = chaos.FaultPlan(seed=0).rule("serve.settle", at=(0,))
    with plan:
        server.step()   # sync mode: dispatch + failed settle + requeue
        assert sorted(server.pending_tickets()) == sorted(tickets)
        assert server.cancel(tickets[1]) is True
        outs = server.flush()
    assert sorted(outs) == sorted([tickets[0], tickets[2]])
    for t, state in ((tickets[0], states[0]), (tickets[2], states[2])):
        np.testing.assert_allclose(np.asarray(outs[t]),
                                   _ref(state, spec, 2), atol=1e-4)
    with pytest.raises(KeyError):
        server.results(tickets[1])
    st = server.stats()
    assert st["faults"]["bucket_failures"] == 1
    assert st["faults"]["retries"] == 1
    assert st["requests"] == 2
