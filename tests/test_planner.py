"""Unified plan/compile API: ExecutionPlan round-tripping, the min-cost
selection property, pins, the backend registry contract, and single-device
compile parity (DESIGN.md §Planner)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.core import engine as eng_mod
from repro.core import stencil_spec as ss
from repro.core.planner import candidate_cost
from repro.core.time_stepper import evolve_compiled
from repro.kernels.ref import stencil_ref

from prop import prop_cases


def _problem(spec=None, grid=(48, 48), boundary="periodic", steps=6, **kw):
    return api.StencilProblem(spec or ss.box(2, 1, seed=0), grid,
                              boundary=boundary, steps=steps, **kw)


def _sequential_ref(x, spec, steps, boundary):
    for _ in range(steps):
        x = stencil_ref(x, spec, boundary=boundary)
    return x


# ---------------------------------------------------------------------------
# ExecutionPlan round-tripping
# ---------------------------------------------------------------------------

def test_plan_json_round_trip_identity():
    p = api.plan(_problem())
    q = api.ExecutionPlan.from_json(p.to_json())
    assert q == p
    assert q.to_json() == p.to_json()
    # the reconstructed spec is the same operator
    np.testing.assert_allclose(np.asarray(q.spec.gather_coeffs),
                               np.asarray(api.box(2, 1, seed=0).gather_coeffs))


def test_plan_json_version_guard():
    import json
    d = json.loads(api.plan(_problem()).to_json())
    d["version"] = 999
    with pytest.raises(ValueError):
        api.ExecutionPlan.from_json(json.dumps(d))


def test_cover_free_backend_scored_once_per_depth():
    """'separable' execution ignores the line cover, so the planner must
    not emit one (identical) candidate per cover option — at most one row
    per (depth, block)."""
    p = api.plan(_problem(ss.star(2, 2, seed=1), steps=6))
    depths = {c.depth for c in p.candidates}
    blocks = {c.block for c in p.candidates}
    assert depths and blocks
    for depth in depths:
        for block in blocks:
            assert sum(1 for c in p.candidates
                       if c.backend == "separable" and c.depth == depth
                       and c.block == block) == 1


def test_depth_one_plan_records_what_compile_executes():
    """When fuse_depth == 1 the fused and base operator coincide; the
    recorded cover must be the one the compiled engine actually uses."""
    p = api.plan(_problem(ss.star(2, 2, seed=1), steps=1))
    assert p.fuse_depth == 1
    assert p.option == p.base_option
    run = api.compile(p)
    if run.engine is not None:
        assert run.engine.plan.option == p.option


@prop_cases(n=6, seed=53)
def test_plan_round_trip_and_min_cost_property(draw):
    """plan() must pick the min modelled cost among ALL enumerated
    (cover x backend x fuse) candidates, and survive JSON round trips."""
    spec = (ss.box if draw.bool() else ss.star)(2, draw.int(1, 2),
                                                seed=draw.int(0, 99))
    boundary = draw.choice(["periodic", "zero", "valid"])
    n = draw.int(24, 64)
    pin = draw.choice([None, "parallel"])
    p = api.plan(_problem(spec, grid=(n, n), boundary=boundary,
                          steps=draw.int(1, 9)), option=pin)
    assert api.ExecutionPlan.from_json(p.to_json()) == p
    best = min(c.t_per_step for c in p.candidates)
    assert p.chosen().t_per_step == best
    # independent recompute of a few candidates agrees with the table
    for c in p.candidates[:: max(1, len(p.candidates) // 3)]:
        again = candidate_cost(_problem(spec, grid=(n, n), boundary=boundary,
                                        steps=p.steps),
                               c.depth, c.option, c.backend, block=c.block,
                               base_option=pin, strategy=c.strategy)
        assert again == c


def test_plan_explain_reports_decisions_and_costs():
    p = api.plan(_problem(ss.star(2, 2, seed=1), steps=8))
    text = p.explain()
    for needle in ("backend=", "cover=", "block=", "fuse=", "schedule=",
                   "halo=", "t_compute", "t_traffic", "t_comm", "t/model",
                   "t/step", "<- chosen"):
        assert needle in text, f"explain() missing {needle!r}:\n{text}"
    # every displayed candidate row carries its modelled per-step cost
    ch = p.chosen()
    assert f"{ch.t_per_step:.3e}" in text


# ---------------------------------------------------------------------------
# Pins and validation
# ---------------------------------------------------------------------------

def test_plan_pins_fuse_backend_option():
    prob = _problem(steps=7)
    p = api.plan(prob, fuse=3, backends=["jnp"], option="parallel")
    assert p.fuse_depth == 3 and p.backend == "jnp"
    assert p.base_option == "parallel"
    assert p.fuse_schedule == (3, 3, 1)
    assert all(c.backend == "jnp" for c in p.candidates)
    with pytest.raises(ValueError):
        api.plan(prob, fuse=0)
    with pytest.raises(ValueError):
        api.plan(prob, fuse=1000)  # beyond the shape/boundary cap
    with pytest.raises(ValueError):
        api.plan(prob, backends=["no_such_backend"])


def test_plan_validation_errors():
    with pytest.raises(ValueError):
        api.StencilProblem(ss.box(2, 1), grid=(16, 16, 16))  # ndim mismatch
    with pytest.raises(ValueError):
        api.StencilProblem(ss.box(2, 1), grid=(16, 16), boundary="bogus")
    with pytest.raises(ValueError):
        api.StencilProblem(ss.box(2, 1), grid=(16, 16), steps=-1)
    with pytest.raises(ValueError):  # grid_axes without mesh
        api.StencilProblem(ss.box(2, 1), grid=(16, 16),
                           grid_axes=("gx", ""))
    # backend that supports no 3-D spec -> no feasible candidate
    with pytest.raises(ValueError):
        api.plan(api.StencilProblem(ss.box(3, 1), grid=(12, 12, 12),
                                    steps=2), backends=["separable"])


def test_plan_pinned_fuse_not_limited_by_search_width():
    """max_depth bounds the SEARCH, not an explicit pin: a feasible pinned
    depth beyond max_depth must plan (and compile) fine."""
    prob = _problem(grid=(64, 64), steps=12)
    p = api.plan(prob, fuse=6, backends=["jnp"])  # > default max_depth=4
    assert p.fuse_depth == 6 and p.fuse_schedule == (6, 6)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(64, 64)),
                    jnp.float32)
    ref = _sequential_ref(x, prob.spec, 12, "periodic")
    np.testing.assert_allclose(np.asarray(api.compile(p)(x)),
                               np.asarray(ref), atol=1e-4)


def test_explain_works_without_the_plans_backends_registered():
    """A shipped plan must render its cost table even in a process that
    never registered the (third-party) backends it mentions."""
    import dataclasses as dc
    p = api.plan(_problem())
    ghost = tuple(dc.replace(c, backend="some_unregistered_plugin")
                  for c in p.candidates[:2])
    q = dc.replace(p, candidates=p.candidates + ghost)
    text = q.explain(top=len(q.candidates))
    assert "some_unregistered_plugin" in text


def test_plan_depth_capped_by_shape_and_boundary():
    # zero boundary caps T at n_min // (2r): n=12, r=1 -> T <= 6 -> max_depth
    p = api.plan(_problem(grid=(12, 12), boundary="zero", steps=40),
                 max_depth=8)
    assert p.fuse_depth <= 6
    assert sum(p.fuse_schedule) == 40
    assert p.halo_width == p.fuse_depth * p.spec.order


# ---------------------------------------------------------------------------
# Backend registry contract
# ---------------------------------------------------------------------------

def test_backend_registry_third_party_plugin():
    """register_backend is the extension point: a custom backend is
    enumerated by the planner, scored by the model, and compiled."""
    calls = []

    def builder(plan, **opts):
        from repro.core import matrixization as mx
        import functools
        calls.append(plan.spec.describe())
        return functools.partial(mx.matrixized_apply, spec=plan.spec,
                                 cover=plan.cover)

    name = "test_custom"
    api.register_backend(name, builder, mxu_efficiency=0.99)
    try:
        assert name in api.backend_names()
        with pytest.raises(ValueError):  # duplicate registration guarded
            api.register_backend(name, builder)
        api.register_backend(name, builder, mxu_efficiency=0.99,
                             overwrite=True)

        prob = _problem(steps=4)
        p = api.plan(prob, backends=[name], fuse=2)
        assert p.backend == name
        run = api.compile(p)
        assert calls, "builder was never invoked"
        x = jnp.asarray(np.random.default_rng(0).normal(size=(48, 48)),
                        jnp.float32)
        ref = _sequential_ref(x, prob.spec, 4, "periodic")
        np.testing.assert_allclose(np.asarray(run(x)), np.asarray(ref),
                                   atol=1e-4)
        # the engine path dispatches through the same registry
        eng = api.StencilEngine(prob.spec, backend=name, boundary="periodic")
        np.testing.assert_allclose(np.asarray(eng(x)),
                                   np.asarray(stencil_ref(x, prob.spec,
                                                          boundary="periodic")),
                                   atol=1e-5)
    finally:
        del eng_mod._BACKENDS[name]


def test_backend_supports_gates_dispatch():
    with pytest.raises(ValueError):
        api.StencilEngine(ss.box(3, 1), backend="separable")


# ---------------------------------------------------------------------------
# compile(): single-device parity with the sequential reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("boundary", ["periodic", "zero", "valid"])
def test_compile_matches_sequential(boundary):
    spec = ss.star(2, 1, seed=4)
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(30, 30)), jnp.float32)
    prob = _problem(spec, grid=(30, 30), boundary=boundary, steps=5)
    run = api.compile(api.plan(prob, backends=["jnp"]))
    ref = _sequential_ref(x, spec, 5, boundary)
    np.testing.assert_allclose(np.asarray(run(x)), np.asarray(ref), atol=1e-4)
    if boundary != "valid":
        assert run.step is not None
        np.testing.assert_allclose(
            np.asarray(run.step(x)),
            np.asarray(stencil_ref(x, spec, boundary=boundary)), atol=1e-5)


def test_compile_is_jit_safe_and_shape_checked():
    prob = _problem(steps=6)
    run = api.compile(api.plan(prob, fuse=3, backends=["jnp"]))
    x = jnp.asarray(np.random.default_rng(2).normal(size=(48, 48)),
                    jnp.float32)
    f = jax.jit(run.fn)
    f(x), f(x), f(x)
    assert f._cache_size() == 1
    with pytest.raises(ValueError):
        run(jnp.ones((20, 20), jnp.float32))  # not the planned grid


def test_compile_default_backend_is_jit_ready():
    """plan() without pins picks the pallas backend; the compiled
    executable must survive jax.jit (kernel planning stays in numpy even
    inside the trace)."""
    spec = ss.box(2, 1, seed=0)
    prob = _problem(spec, grid=(24, 24), steps=3)
    p = api.plan(prob)
    assert p.backend == "pallas"
    run = api.compile(p)
    x = jnp.asarray(np.random.default_rng(6).normal(size=(24, 24)),
                    jnp.float32)
    out = jax.jit(run.fn)(x)
    ref = _sequential_ref(x, spec, 3, "periodic")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_compile_zero_steps_is_identity():
    prob = _problem(steps=0)
    p = api.plan(prob)
    assert p.fuse_schedule == ()
    run = api.compile(p)
    x = jnp.ones((48, 48), jnp.float32)
    np.testing.assert_array_equal(np.asarray(run(x)), np.asarray(x))


def test_evolve_compiled_and_engine_from_plan():
    spec = ss.box(2, 1, seed=5)
    prob = _problem(spec, steps=6)
    p = api.plan(prob, backends=["jnp"])
    run = api.compile(p)
    x = jnp.asarray(np.random.default_rng(13).normal(size=(48, 48)),
                    jnp.float32)
    res = evolve_compiled(run, x)
    np.testing.assert_allclose(np.asarray(res.state),
                               np.asarray(_sequential_ref(x, spec, 6,
                                                          "periodic")),
                               atol=1e-4)
    assert int(res.steps_run) == 6
    # the engine compatibility constructor honours the plan's decisions
    eng = api.StencilEngine.from_execution_plan(p)
    assert eng.plan.backend == p.backend
    assert eng.plan.option == p.base_option
    assert eng.plan.block == p.block
