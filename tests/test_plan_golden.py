"""Golden plan report: the planner's decisions + modelled costs for the
PAPER_SUITE against TPU_V5E are frozen in ``tests/golden/plan_report.txt``.

Any cost-model or decision change must come with a reviewed golden update:
regenerate with ``make plan-report > tests/golden/plan_report.txt`` (or
``python -m repro.launch.plan_report``).  Tier-1 (fast, pure model — no
compilation)."""
import difflib
import os

from repro.launch.plan_report import generate_report

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "plan_report.txt")


def test_plan_report_matches_golden():
    with open(GOLDEN) as f:
        golden = f.read()
    current = generate_report()
    if current != golden:
        diff = "\n".join(difflib.unified_diff(
            golden.splitlines(), current.splitlines(),
            fromfile="tests/golden/plan_report.txt",
            tofile="generated", lineterm="", n=2))
        raise AssertionError(
            "plan report drifted from the golden — if the cost-model change "
            "is intended, regenerate with `make plan-report > "
            f"tests/golden/plan_report.txt`\n{diff}")


def test_plan_report_covers_whole_suite():
    from repro.core.stencil_spec import PAPER_SUITE
    current = generate_report()
    for name in PAPER_SUITE():
        assert f"## {name}" in current
    assert current.count("<- chosen") == len(PAPER_SUITE())
