"""End-to-end behaviour: a small model actually learns on the synthetic
pipeline; checkpoint-resume reproduces the uninterrupted run exactly."""
import shutil

import numpy as np
import pytest

import jax

from repro.configs.base import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import adamw
from repro.train.trainer import Trainer, TrainerConfig


def test_training_reduces_loss(tmp_path):
    cfg = get_smoke_config("tinyllama_1_1b")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      seed=11)
    tr = Trainer(cfg, dcfg,
                 TrainerConfig(total_steps=40, checkpoint_every=100,
                               checkpoint_dir=str(tmp_path), log_every=1,
                               async_checkpoint=False),
                 optimizer=adamw(lr=1e-3))
    tr.run()
    losses = [m["loss"] for m in tr.metrics_log]
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.5, (first, last)


def test_resume_bitwise_equals_uninterrupted(tmp_path):
    cfg = get_smoke_config("gemma_2b")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4,
                      seed=3)

    d1 = str(tmp_path / "run1")
    tr1 = Trainer(cfg, dcfg, TrainerConfig(total_steps=8, checkpoint_every=4,
                                           checkpoint_dir=d1, log_every=1,
                                           async_checkpoint=False))
    final1 = tr1.run()

    # interrupted run: stop at 4 (simulated by total_steps=4), then resume
    d2 = str(tmp_path / "run2")
    tr2a = Trainer(cfg, dcfg, TrainerConfig(total_steps=4, checkpoint_every=4,
                                            checkpoint_dir=d2, log_every=1,
                                            async_checkpoint=False))
    tr2a.run()
    tr2b = Trainer(cfg, dcfg, TrainerConfig(total_steps=8, checkpoint_every=4,
                                            checkpoint_dir=d2, log_every=1,
                                            async_checkpoint=False))
    final2 = tr2b.run()

    for a, b in zip(jax.tree.leaves(final1.params), jax.tree.leaves(final2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
