"""Cross-strategy parity harness (the ISSUE-8 gate).

One reusable entry point — :func:`assert_sweep_parity` — checks a fused
engine sweep against the gather-mode oracle (``time_stepper
.reference_evolve``) for ANY spec kind (constant / varying-coefficient /
masked), any boundary, either fuse strategy, and an optional folded batch
axis.  The bars are the repo-wide ones:

* ``atol=1e-4`` against the iterated gather oracle (XLA:CPU contracts the
  banded dots with FMA, so exact equality across ``steps`` applications is
  not the right bar — see DESIGN.md §Numerics);
* BIT-exactness of a batched sweep against ``jax.vmap`` of the same
  closure (folding states must not change the per-state arithmetic);
* an ILLEGAL explicit (strategy, depth) pin — e.g. operator fusion at
  depth > 1 over a varying-coefficient spec — must raise ``ValueError``
  from the engine, never silently apply the constant-coefficient fused
  operator.  The harness asserts the raise, so every parity sweep doubles
  as the fusion-legality regression.

Seeded generators (``draw_base_spec`` / ``with_scenario`` /
``draw_scenario_spec``) plug into ``prop.prop_cases`` for randomized
tier-1 coverage; ``tests/test_parity.py`` drives them and
``tests/test_batched.py`` routes its batched parity loops through the same
entry point.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import stencil_spec as ss
from repro.core import temporal
from repro.core.engine import StencilEngine
from repro.core.time_stepper import reference_evolve

__all__ = ["SCENARIOS", "parity_grid", "draw_base_spec", "with_scenario",
           "draw_scenario_spec", "assert_sweep_parity"]

#: Coefficient/domain scenarios a spec can carry (plan dimensions, ISSUE 8).
SCENARIOS = ("constant", "varying", "masked", "varying+masked")


def parity_grid(spec, steps: int = 4) -> tuple[int, ...]:
    """Smallest grid the full parity matrix runs on: 'valid' shrinks the
    state 2r per step, so high-order 3-D cells need headroom."""
    n = 40 if spec.ndim == 2 else max(20, 2 * spec.order * steps + 4)
    return (n,) * spec.ndim


def draw_base_spec(draw):
    """Seeded constant-coefficient spec: 2-D/3-D star or box, r in {1, 2}
    (2-D) or r=1 (3-D keeps interpret-mode runtime in budget)."""
    ndim = draw.choice((2, 3))
    order = draw.choice((1, 2)) if ndim == 2 else 1
    factory = ss.star if draw.bool() else ss.box
    return factory(ndim, order, seed=draw.int(0, 9999))


def with_scenario(spec, grid, kind: str, seed: int = 0):
    """Attach a seeded coefficient field and/or domain mask on ``grid``."""
    if kind not in SCENARIOS:
        raise ValueError(f"unknown scenario {kind!r}; choose {SCENARIOS}")
    if kind == "constant":
        return spec
    field = (ss.random_coeff_field(grid, seed=seed)
             if "varying" in kind else None)
    mask = (ss.random_domain_mask(grid, seed=seed + 1)
            if "mask" in kind else None)
    if field is not None:
        return spec.with_field(field, domain_mask=mask)
    return spec.with_mask(mask)


def draw_scenario_spec(draw, steps: int = 4):
    """Seeded (spec, grid) pair covering all four scenario kinds."""
    base = draw_base_spec(draw)
    grid = parity_grid(base, steps)
    kind = draw.choice(SCENARIOS)
    return with_scenario(base, grid, kind, seed=draw.int(0, 9999)), grid


def assert_sweep_parity(spec, boundary: str, strategy: str = "auto",
                        depth="auto", batch: int = 0, *, steps: int = 4,
                        grid: tuple[int, ...] | None = None, seed: int = 0,
                        backend: str = "pallas",
                        block: tuple[int, ...] | None = None,
                        atol: float = 1e-4):
    """Fused-sweep parity for one (spec, boundary, strategy, depth, batch).

    ``batch=0`` runs a single un-batched state; ``batch>=1`` folds that
    many states and additionally requires bit-exactness against
    ``jax.vmap`` of the same sweep closure.  ``depth`` is the fuse pin
    (int) or ``"auto"``.  If the explicit (strategy, depth) pin is illegal
    for the spec/boundary (``temporal.fusion_legal``), the engine MUST
    refuse with ``ValueError`` — the harness asserts that and returns
    ``None``; otherwise it returns the sweep output after the checks pass.
    """
    if grid is None:
        grid = parity_grid(spec, steps)
    grid = tuple(grid)
    if block is None:
        block = (16, 16) if spec.ndim == 2 else (4, 8, 8)
    eng = StencilEngine(spec, backend=backend, block=block,
                        boundary=boundary)

    label = (f"{spec.describe()} boundary={boundary} strategy={strategy} "
             f"depth={depth} batch={batch} steps={steps}")
    pinned = strategy != "auto" and isinstance(depth, int)
    if pinned and not temporal.fusion_legal(spec, boundary, strategy, depth):
        try:
            fn = eng.sweep_fn(steps, fuse=depth, grid=grid,
                              strategy=strategy)
            fn(jnp.zeros(grid, jnp.float32))
        except ValueError:
            return None
        raise AssertionError(
            f"illegal fused pin silently accepted (would apply the "
            f"constant-coefficient operator): {label}")

    rng = np.random.default_rng(seed)
    shape = ((batch,) + grid) if batch else grid
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    fn = eng.sweep_fn(steps, fuse=depth, grid=grid, strategy=strategy)
    out = fn(x)
    ref = reference_evolve(spec, x, steps, boundary)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=atol,
        err_msg=f"sweep diverged from gather oracle: {label}")
    if batch:
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(jax.vmap(fn)(x)),
            err_msg=f"batched sweep not bit-exact vs vmap: {label}")
    return out
