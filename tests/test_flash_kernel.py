"""Pallas flash-attention kernel vs dense oracle (interpret mode)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention, flash_attention_pallas

from prop import prop_cases


def dense_ref(q, k, v, causal=True):
    dh = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    if causal:
        n = q.shape[2]
        s = jnp.where(jnp.tril(jnp.ones((n, n), bool)), s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


@prop_cases(n=8, seed=41)
def test_flash_matches_dense(draw):
    b = draw.int(1, 2)
    h = draw.int(1, 3)
    nblk = draw.int(1, 4)
    blk = draw.choice([16, 32])
    s = nblk * blk
    dh = draw.choice([8, 16])
    causal = draw.bool()
    dt = draw.choice([jnp.float32, jnp.bfloat16])
    q = jnp.asarray(draw.normal((b, h, s, dh)), dt)
    k = jnp.asarray(draw.normal((b, h, s, dh)), dt)
    v = jnp.asarray(draw.normal((b, h, s, dh)), dt)
    out = flash_attention_pallas(q, k, v, block_q=blk, block_k=blk,
                                 causal=causal)
    ref = dense_ref(q, k, v, causal)
    atol = 2e-5 if dt == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_flash_grads_match_dense():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)

    def loss_f(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v)))

    def loss_r(q, k, v):
        return jnp.sum(jnp.sin(dense_ref(q, k, v)))

    gf = jax.grad(loss_f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
